"""DSP substrate tests: simulator physics, workloads, baselines, anomaly."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property-based tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import RecoveryTracker
from repro.dsp import (ClusterModel, DS2Controller, JobConfig,
                       ReactiveController, SimJob, baseline_config, constant,
                       measure_recovery, tsw_like, ysb_like)

MODEL = ClusterModel()


class TestCapacitySurface:
    @given(w=st.integers(4, 24), c=st.integers(1, 3))
    @settings(max_examples=30, deadline=None)
    def test_monotone_in_workers_and_cores(self, w, c):
        base = MODEL.capacity(JobConfig(workers=w, cpu_cores=c))
        if w < 24:
            assert MODEL.capacity(JobConfig(workers=w + 1, cpu_cores=c)) \
                >= base
        assert MODEL.capacity(JobConfig(workers=w, cpu_cores=min(c + 1, 3))) \
            >= base

    def test_memory_has_diminishing_returns(self):
        caps = [MODEL.capacity(JobConfig(memory_mb=m))
                for m in (1024, 2048, 4096)]
        assert caps[0] < caps[1] < caps[2]
        assert caps[1] - caps[0] > caps[2] - caps[1]

    def test_short_checkpoint_interval_taxes_throughput(self):
        slow = MODEL.capacity(JobConfig(checkpoint_interval_s=10))
        fast = MODEL.capacity(JobConfig(checkpoint_interval_s=90))
        assert fast > slow

    def test_parallelism_cap(self):
        a = MODEL.capacity(JobConfig(workers=24, task_slots=2))
        b = MODEL.capacity(JobConfig(workers=12, task_slots=2))
        # both have 24 effective slots; 24 workers were capped
        assert a == pytest.approx(b * 2, rel=0.5)

    def test_static_cmax_covers_paper_range(self):
        # the paper's workloads peak at ~80K ev/s; C_max must hold them
        assert MODEL.capacity(JobConfig()) > 82_000 / 0.75


class TestSimJob:
    def test_underprovision_builds_lag(self):
        job = SimJob(MODEL, JobConfig(workers=4), seed=0)
        for _ in range(100):
            m = job.step(50_000, 5.0)
        assert m["consumer_lag"] > 1e5
        assert m["latency"] > 10.0

    def test_overprovision_keeps_low_latency(self):
        job = SimJob(MODEL, JobConfig(), seed=0)
        lats = [job.step(30_000, 5.0)["latency"] for _ in range(100)]
        assert np.mean(lats[10:]) < 1.5

    def test_recovery_time_reasonable_at_cmax(self):
        job = SimJob(MODEL, JobConfig(), seed=0)
        for _ in range(50):
            job.step(50_000, 5.0)
        r = measure_recovery(job, lambda t: 50_000, 0.0, 5.0)
        assert r is not None and 60.0 <= r <= 180.0

    def test_reconfigure_causes_downtime(self):
        job = SimJob(MODEL, JobConfig(), seed=0)
        job.step(30_000, 5.0)
        job.reconfigure(JobConfig(workers=12))
        m = job.step(30_000, 5.0)
        assert m["down"] == 1.0

    @given(rate=st.floats(20_000, 80_000))
    @settings(max_examples=20, deadline=None)
    def test_lag_never_negative(self, rate):
        job = SimJob(MODEL, JobConfig(workers=8), seed=1)
        for _ in range(50):
            m = job.step(rate, 5.0)
            assert m["consumer_lag"] >= 0.0


class TestWorkloads:
    def test_ysb_range_and_variability(self):
        tr = ysb_like(duration_s=4 * 3600, dt_s=5.0)
        assert tr.rates.min() >= 24_000 and tr.rates.max() <= 82_000
        assert tr.rates.std() > 3_000          # high variability

    def test_tsw_seasonal_and_trend(self):
        tr = tsw_like(duration_s=18 * 3600, dt_s=10.0)
        n = len(tr.rates)
        # weak upward trend: second half mean > first half mean
        assert tr.rates[n // 2:].mean() > tr.rates[:n // 2].mean()
        # seasonality: three repetitions -> autocorrelation at period
        period = n // 3
        a = tr.rates[:-period] - tr.rates[:-period].mean()
        b = tr.rates[period:] - tr.rates[period:].mean()
        rho = (a * b).sum() / np.sqrt((a * a).sum() * (b * b).sum())
        assert rho > 0.5

    def test_rate_at_clamps(self):
        tr = constant(1000.0, duration_s=100.0, dt_s=5.0)
        assert tr.rate_at(-5) == 1000.0
        assert tr.rate_at(1e9) == 1000.0


class TestBaselines:
    def _window(self, util, thr=30_000.0, rate=40_000.0):
        return [{"utilization": util, "usage_cpu": 10.0, "throughput": thr,
                 "rate": rate}] * 12

    def test_reactive_scales_up_immediately(self):
        r = ReactiveController()
        new = r.decide(100.0, self._window(0.9), baseline_config(8))
        assert new is not None and new.workers > 8

    def test_reactive_downscale_needs_stabilization(self):
        r = ReactiveController()
        cur = baseline_config(20)
        assert r.decide(100.0, self._window(0.1), cur) is None
        assert r.decide(200.0, self._window(0.1), cur) is None
        new = r.decide(500.0, self._window(0.1), cur)
        assert new is not None and new.workers < 20

    def test_ds2_within_boundary_no_change(self):
        d = DS2Controller()
        assert d.decide(500.0, self._window(0.35), baseline_config(10)) is None

    def test_ds2_pauses_after_scaling(self):
        d = DS2Controller()
        new = d.decide(500.0, self._window(0.9), baseline_config(8))
        assert new is not None
        # blind during restart+catchup pause
        assert d.decide(600.0, self._window(0.9), new) is None


class TestRecoveryTracker:
    def test_detects_outage_span(self):
        tr = RecoveryTracker()
        rng = np.random.default_rng(0)
        t = 0.0
        for _ in range(60):     # healthy warmup
            t += 5.0
            tr.observe(t, {"throughput": 50_000 + rng.normal(0, 200),
                           "consumer_lag": 1_000 + rng.normal(0, 50)})
        assert not tr.in_anomaly
        start = t
        for _ in range(20):     # outage: throughput collapses, lag explodes
            t += 5.0
            tr.observe(t, {"throughput": 0.0,
                           "consumer_lag": 50_000 * (t - start)})
        assert tr.in_anomaly
        for _ in range(40):     # recovered
            t += 5.0
            tr.observe(t, {"throughput": 50_000 + rng.normal(0, 200),
                           "consumer_lag": 1_000 + rng.normal(0, 50)})
        assert tr.last_recovery_s is not None
        assert 80.0 <= tr.last_recovery_s <= 220.0

"""Batched-vs-scalar modeling agreement tests.

The batched GPBank fit and the jitted EHVI path must reproduce the scalar
scipy/NumPy reference oracles: posterior mean/variance within tolerance,
identical Pareto subsets, and — the end-to-end guarantee the controller
relies on — the same selected profiling batch.
"""
import numpy as np
import pytest

from repro.core import (GP, GPBank, ModelBank, Segment, SegmentStore,
                        batched_posterior, ehvi_2d, ehvi_2d_batch,
                        pareto_front_2d, pareto_front_mask_2d,
                        select_profiling_batch)
from repro.core.demeter import FIT_MAX_ITER, FIT_RESTARTS
from repro.core.segments import LATENCY, METRICS, RECOVERY, USAGE


def _random_segments(rng, n_segments=6, dim=5):
    """Synthetic per-segment datasets shaped like controller training data."""
    datasets, seeds = [], []
    for i in range(n_segments):
        n = int(rng.integers(5, 20))
        x = rng.uniform(0, 1, (n, dim))
        level = 1.0 + 0.3 * i
        y = (level * (1.2 - x[:, 0]) + 0.4 * x[:, 1] ** 2
             + rng.normal(0, 0.05, n))
        datasets.append((x, y))
        seeds.append(i * 131)
    return datasets, seeds


class TestGPBankFit:
    @pytest.fixture(scope="class")
    def fitted(self):
        rng = np.random.default_rng(7)
        datasets, seeds = _random_segments(rng)
        scalars = [GP.fit(x, y, restarts=FIT_RESTARTS,
                          max_iter=FIT_MAX_ITER, seed=s)
                   for (x, y), s in zip(datasets, seeds)]
        bank = GPBank.fit(datasets, restarts=FIT_RESTARTS,
                          max_iter=FIT_MAX_ITER, seeds=seeds)
        return datasets, scalars, bank

    def test_posterior_agrees_with_scalar_oracle(self, fitted, rng):
        """Bank members' posterior mean/var match the scipy-fitted GPs."""
        datasets, scalars, bank = fitted
        xq = rng.uniform(0, 1, (128, 5))
        mu_b, var_b = bank.posterior(xq)
        for i, ((_, y), gp) in enumerate(zip(datasets, scalars)):
            mu, var = gp.posterior(xq)
            scale = np.std(y) or 1.0
            assert np.max(np.abs(mu - mu_b[i])) / scale < 0.05, \
                f"member {i} posterior mean drifted from the scipy fit"
            assert np.max(np.abs(var - var_b[i])) / scale ** 2 < 0.05, \
                f"member {i} posterior variance drifted from the scipy fit"

    def test_members_roundtrip_as_scalar_gps(self, fitted, rng):
        """A sliced-out member behaves like a plain GP (same API, finite)."""
        _, _, bank = fitted
        xq = rng.uniform(0, 1, (16, 5))
        mu_b, var_b = bank.posterior(xq)
        for i in range(bank.n_members):
            g = bank.member(i)
            mu, var = g.posterior(xq)
            np.testing.assert_allclose(mu, mu_b[i], rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(var, var_b[i], rtol=1e-3, atol=1e-5)
            s = g.loo_samples(8, np.random.default_rng(0))
            assert np.isfinite(s).all()

    def test_batched_posterior_matches_per_gp_loop(self, fitted, rng):
        _, scalars, _ = fitted
        xq = rng.uniform(0, 1, (64, 5))
        mu_b, var_b = batched_posterior(scalars, xq)
        for i, gp in enumerate(scalars):
            mu, var = gp.posterior(xq)
            np.testing.assert_allclose(mu, mu_b[i], rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(var, var_b[i], rtol=1e-3, atol=1e-5)

    def test_single_dataset_bank(self, rng):
        x = rng.uniform(0, 1, (12, 3))
        y = np.sin(3 * x[:, 0]) + x[:, 1]
        bank = GPBank.fit([(x, y)], seeds=[5])
        mu, var = bank.posterior(x)
        assert mu.shape == (1, 12)
        assert np.all(var > 0)
        assert np.abs(mu[0] - y).max() < 0.5

    def test_rejects_empty_and_mixed_dims(self, rng):
        with pytest.raises(ValueError, match="at least one"):
            GPBank.fit([])
        a = (rng.uniform(0, 1, (5, 2)), rng.normal(0, 1, 5))
        b = (rng.uniform(0, 1, (5, 3)), rng.normal(0, 1, 5))
        with pytest.raises(ValueError, match="dim"):
            GPBank.fit([a, b])


class TestBatchedEHVI:
    def test_matches_numpy_oracle_across_random_fronts(self, rng):
        B, n = 6, 32
        mu = rng.uniform(0, 5, (B, n, 2))
        var = rng.uniform(0.01, 1.0, (B, n, 2))
        fronts = [rng.uniform(0, 4, (int(rng.integers(0, 10)), 2))
                  for _ in range(B)]
        refs = np.full((B, 2), 5.0)
        out = ehvi_2d_batch(mu, var, fronts, refs)
        for i in range(B):
            want = ehvi_2d(mu[i], var[i], fronts[i], (5.0, 5.0))
            np.testing.assert_allclose(out[i], want, rtol=1e-3, atol=1e-5)

    def test_empty_front_row(self, rng):
        mu = rng.uniform(0, 2, (1, 8, 2))
        var = np.full((1, 8, 2), 0.25)
        out = ehvi_2d_batch(mu, var, [np.zeros((0, 2))],
                            np.array([[3.0, 3.0]]))
        want = ehvi_2d(mu[0], var[0], np.zeros((0, 2)), (3.0, 3.0))
        np.testing.assert_allclose(out[0], want, rtol=1e-3, atol=1e-5)

    def test_pareto_mask_equals_front(self, rng):
        for _ in range(25):
            k = int(rng.integers(1, 16))
            pts = rng.uniform(0, 4, (k, 2))
            mask = pareto_front_mask_2d(pts[None])[0]
            got = np.sort(pts[mask], axis=0)
            want = np.sort(pareto_front_2d(pts), axis=0)
            np.testing.assert_allclose(got, want)

    def test_pareto_mask_respects_validity(self, rng):
        pts = np.array([[[1.0, 1.0], [0.1, 0.1], [2.0, 0.5]]])
        valid = np.array([[True, False, True]])
        mask = pareto_front_mask_2d(pts, valid)
        # the dominated-but-invalid point must not be selected nor shadow
        assert not mask[0, 1]
        assert mask[0, 0]


class TestSelectionAgreement:
    """The controller-facing guarantee: same profiling batch either way."""

    def _posteriors(self, gps_u, gps_l):
        def post(x):
            mu_u, var_u = gps_u.posterior(x)
            mu_l, var_l = gps_l.posterior(x)
            return (np.stack([mu_u, mu_l], 1), np.stack([var_u, var_l], 1))
        return post

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_same_profiling_batch_selected(self, seed):
        rng = np.random.default_rng(seed)
        n = 15
        x = rng.uniform(0, 1, (n, 4))
        usage = 1.5 - x[:, 0] + 0.2 * x[:, 1] + rng.normal(0, 0.03, n)
        lat = 0.5 + x[:, 0] ** 2 + rng.normal(0, 0.03, n)

        su = GP.fit(x, usage, restarts=FIT_RESTARTS,
                    max_iter=FIT_MAX_ITER, seed=3)
        sl = GP.fit(x, lat, restarts=FIT_RESTARTS,
                    max_iter=FIT_MAX_ITER, seed=4)
        bank = GPBank.fit([(x, usage), (x, lat)], restarts=FIT_RESTARTS,
                          max_iter=FIT_MAX_ITER, seeds=[3, 4])
        bu, bl = bank.member(0), bank.member(1)

        cand = rng.uniform(0, 1, (96, 4))
        front = np.stack([usage, lat], 1)
        ref = (float(usage.max()) * 1.2, float(lat.max()) * 1.2)

        picked_scalar = select_profiling_batch(
            cand, self._posteriors(su, sl), None, front, ref, q=3,
            backend="numpy")
        picked_bank = select_profiling_batch(
            cand, self._posteriors(bu, bl), None, front, ref, q=3,
            backend="jax")
        assert picked_scalar == picked_bank, \
            "batched fit + jitted EHVI changed the profiling batch"


class TestModelBankBackends:
    def _store_with_data(self, rng, n_obs=8):
        store = SegmentStore(10_000.0)
        for i in range(n_obs):
            x = rng.uniform(0, 1, 3)
            metrics = {USAGE: float(1.5 - x[0] + rng.normal(0, 0.02)),
                       LATENCY: float(0.5 + x[0] ** 2),
                       RECOVERY: float(60.0 + 100 * x[1])}
            store.record({"a": i}, x, 15_000.0, metrics)
        return store

    def test_bank_and_scalar_backends_agree(self, rng):
        store = self._store_with_data(rng)
        seg = store.segment_for(15_000.0)
        mb_bank = ModelBank(store, fit_backend="bank")
        mb_scalar = ModelBank(store, fit_backend="scalar")
        xq = rng.uniform(0, 1, (32, 3))
        for metric in METRICS:
            gb = mb_bank.gp(seg, metric)
            gs = mb_scalar.gp(seg, metric)
            assert (gb is None) == (gs is None)
            if gb is None:
                continue
            mu_b, _ = gb.posterior(xq)
            mu_s, _ = gs.posterior(xq)
            scale = np.std(seg.data(metric)[1]) or 1.0
            assert np.max(np.abs(mu_b - mu_s)) / scale < 0.05

    def test_refresh_fits_everything_stale(self, rng):
        store = self._store_with_data(rng)
        mb = ModelBank(store)
        n = mb.refresh()
        assert n == len(METRICS)
        assert mb.refresh() == 0              # now fresh
        seg = store.segment_for(15_000.0)
        assert mb.gp(seg, USAGE) is not None  # cache hit, no refit
        assert mb.n_fits == 0                 # all fits were batched

    def test_batch_refresh_spans_banks(self, rng):
        stores = [self._store_with_data(rng) for _ in range(3)]
        banks = [ModelBank(s) for s in stores]
        n, wall = ModelBank.batch_refresh(banks)
        assert n == 3 * len(METRICS)
        assert wall >= 0.0
        n2, _ = ModelBank.batch_refresh(banks)
        assert n2 == 0

    def test_version_staleness(self, rng):
        store = self._store_with_data(rng, n_obs=12)
        seg = store.segment_for(15_000.0)
        mb = ModelBank(store)
        g1 = mb.gp(seg, USAGE)
        assert mb.gp(seg, USAGE) is g1        # cached by version
        v = seg.version
        x = rng.uniform(0, 1, 3)
        store.record({"a": 99}, x, 15_000.0, {USAGE: 0.7})
        assert seg.version == v + 1           # 12 -> 13 is < 10% growth
        assert mb.gp(seg, USAGE) is g1        # fresh enough, no refit

    def test_rejects_unknown_backend(self):
        with pytest.raises(ValueError, match="unknown fit backend"):
            ModelBank(SegmentStore(10_000.0), fit_backend="torch")

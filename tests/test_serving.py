"""Serving engine tests: continuous batching correctness + manager props."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property-based tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serving import (KVCacheManager, Request, ServingCluster,
                           ServingEngine)
from repro.serving.autoscale import ClusterModelParams, ReplicaProfile


class TestKVCacheManager:
    def test_allocate_release_cycle(self):
        m = KVCacheManager(n_slots=2, max_len=64)
        a = m.allocate("a", 10, 5)
        b = m.allocate("b", 10, 5)
        assert {a, b} == {0, 1}
        assert m.allocate("c", 10, 5) is None
        m.release(a)
        assert m.allocate("c", 10, 5) == a

    def test_rejects_oversized(self):
        m = KVCacheManager(n_slots=1, max_len=16)
        with pytest.raises(ValueError):
            m.allocate("x", 10, 10)

    @given(st.lists(st.tuples(st.integers(1, 20), st.integers(1, 10)),
                    min_size=1, max_size=30))
    @settings(max_examples=30, deadline=None)
    def test_occupancy_invariants(self, reqs):
        m = KVCacheManager(n_slots=4, max_len=64)
        for i, (plen, mtok) in enumerate(reqs):
            m.allocate(f"r{i}", plen, mtok)
            assert 0.0 <= m.occupancy() <= 1.0
            assert len(m.active()) <= 4


@pytest.mark.parametrize("arch", ["deepseek_7b", "mamba2_1p3b",
                                  "deepseek_v2_lite_16b"])
def test_continuous_batching_matches_sequential(arch):
    """Ragged engine decoding == one-request-at-a-time decoding."""
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    eng = ServingEngine(cfg, params, n_slots=3, max_len=96)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n))
               for n in (8, 12, 16, 9, 11)]
    for i, pr in enumerate(prompts):
        eng.submit(Request(f"r{i}", pr, max_tokens=6, arrival_s=0.0))
    for _ in range(40):
        eng.admit()
        if eng.step() == 0 and not eng.queue:
            break
    assert eng.metrics.completed == len(prompts)

    for i, pr in enumerate(prompts):
        cache = init_cache(cfg, 1, 96, dtype=jnp.float32)
        lg, cache = prefill(params, cfg,
                            {"tokens": jnp.asarray(pr, jnp.int32)[None]},
                            cache)
        toks = [int(jnp.argmax(lg[0]))]
        for _ in range(5):
            lg, cache = decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache)
            toks.append(int(jnp.argmax(lg[0])))
        assert toks == eng.requests[f"r{i}"].output, f"{arch} req {i}"


class TestServingCluster:
    PROFILE = ReplicaProfile(decode_step_s=0.02, prefill_s=0.05,
                             base_slots=8)

    def test_capacity_monotone_in_replicas(self):
        c = ServingCluster(self.PROFILE, ClusterModelParams())
        caps = [c.capacity_rps({**c.config, "replicas": r})
                for r in (2, 4, 8)]
        assert caps[0] < caps[1] < caps[2]

    def test_tp_speeds_up_decode(self):
        c = ServingCluster(self.PROFILE, ClusterModelParams())
        a = c.capacity_rps({**c.config, "tp_degree": 1})
        b = c.capacity_rps({**c.config, "tp_degree": 8})
        assert b > a

    def test_failure_and_catchup(self):
        c = ServingCluster(self.PROFILE, ClusterModelParams())
        for _ in range(20):
            c.step(5.0, 5.0)
        c.inject_failure()
        assert c.downtime_left_s > 0
        for _ in range(200):
            c.step(5.0, 5.0)
            if c.caught_up:
                break
        assert c.caught_up

    def test_overload_backlogs(self):
        c = ServingCluster(self.PROFILE, ClusterModelParams())
        cap = c.capacity_rps()
        for _ in range(50):
            m = c.step(cap * 2.0, 5.0)
        assert m["consumer_lag"] > 0
        assert m["latency"] > self.PROFILE.prefill_s

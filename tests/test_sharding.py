"""Sharding rule tests: spec structure, sanitization, launch spec coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, smoke_config
from repro.distributed.sharding import (param_specs, sanitize_spec, shard,
                                        sharding_context)
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_mesh
from repro.launch.specs import (SHAPES, batch_specs, cell_supported,
                                input_specs)
from repro.models import init_params


def tiny_mesh():
    # 1 real device: a (1, 1) mesh exercises all the code paths.
    return make_mesh((1, 1), ("data", "model"))


class TestParamSpecs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_specs_tree_matches_params(self, arch):
        cfg = smoke_config(arch)
        params = jax.eval_shape(
            lambda k: init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(params)
        # identical tree structure
        assert jax.tree.structure(specs) == jax.tree.structure(params)
        # every spec rank <= leaf rank
        for s, l in zip(jax.tree.leaves(specs), jax.tree.leaves(params)):
            assert len(s) <= l.ndim

    def test_core_rules(self):
        cfg = smoke_config("deepseek_7b")
        params = jax.eval_shape(
            lambda k: init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(params)
        # stacked attention: (L, d, H*hd) -> (None, data, model)
        assert specs["stack"]["mixer"]["wq"]["w"] == P(None, "data", "model")
        assert specs["stack"]["mixer"]["wo"]["w"] == P(None, "model", "data")
        assert specs["stack"]["ffn"]["down"]["w"] == P(None, "model", "data")
        assert specs["embed"]["table"] == P("model", None)
        assert specs["final_norm"]["scale"] == P(None)

    def test_moe_expert_rules(self):
        cfg = smoke_config("deepseek_moe_16b")
        params = jax.eval_shape(
            lambda k: init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(params)
        assert specs["stack"]["ffn"]["experts"]["gate"]["w"] \
            == P(None, "model", "data", None)
        assert specs["stack"]["ffn"]["experts"]["down"]["w"] \
            == P(None, "model", None, "data")


class TestSanitize:
    def test_drops_non_dividing_axes(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        # 1 divides everything on a (1,1) mesh
        assert sanitize_spec(mesh, P("data", "model"), (7, 5)) \
            == P("data", "model")

    def test_drops_on_bigger_virtual_mesh(self):
        import jax.sharding as shd
        devs = np.array(jax.devices()[:1] * 16).reshape(4, 4) \
            if jax.device_count() >= 16 else None
        # portable check via the pure function with a fake mesh-like object
        class FakeMesh:
            shape = {"data": 4, "model": 4}
        assert sanitize_spec(FakeMesh(), P("data", "model"), (8, 6)) \
            == P("data", None)
        assert sanitize_spec(FakeMesh(), P(("data", "model"),), (15,)) \
            == P(None)
        assert sanitize_spec(FakeMesh(), P(("data", "model"),), (16,)) \
            == P(("data", "model"))


class TestShardHook:
    def test_noop_without_context(self):
        x = jnp.ones((4, 4))
        y = shard(x, "batch", "mlp")
        assert y is x

    def test_right_alignment_in_context(self):
        mesh = tiny_mesh()
        with sharding_context(mesh):
            x = jnp.ones((2, 3, 4))
            y = shard(x, "batch", "mlp")   # shorter spec: pads left
            assert y.shape == x.shape
            z = shard(jnp.ones((4,)), "batch", None, "mlp")  # longer: trims
            assert z.shape == (4,)


class TestLaunchSpecs:
    def test_cell_rules(self):
        from repro.configs import get_config
        hub = get_config("hubert_xlarge")
        assert not cell_supported(hub, "decode_32k")[0]
        assert not cell_supported(hub, "long_500k")[0]
        assert cell_supported(hub, "prefill_32k")[0]
        nemo = get_config("mistral_nemo_12b")
        assert not cell_supported(nemo, "long_500k")[0]
        mamba = get_config("mamba2_1p3b")
        assert cell_supported(mamba, "long_500k")[0]

    def test_assigned_shape_table(self):
        assert SHAPES["train_4k"] == (4096, 256)
        assert SHAPES["prefill_32k"] == (32768, 32)
        assert SHAPES["decode_32k"] == (32768, 128)
        assert SHAPES["long_500k"] == (524288, 1)

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_batch_specs_no_allocation(self, arch):
        from repro.configs import get_config
        cfg = get_config(arch)
        b = batch_specs(cfg, 256, 4096, training=True)
        for leaf in jax.tree.leaves(b):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        if cfg.frontend and cfg.frontend.kind == "audio":
            assert b["frames"].shape == (256, 4096, cfg.frontend.d_in)
        else:
            assert b["tokens"].shape == (256, 4096)


class TestCollectiveParser:
    def test_counts_result_bytes(self):
        hlo = """
          %ag = bf16[16,128] all-gather(%x), replica_groups={}
          %ar.1 = f32[64] all-reduce(%y), to_apply=%add
          %t = (f32[8,8], f32[8,8]) all-to-all(%a, %b)
          %cp = u8[32] collective-permute(%z)
          %not_a_coll = f32[4] add(%p, %q)
        """
        got = collective_bytes(hlo)
        assert got["all-gather"] == 16 * 128 * 2
        assert got["all-reduce"] == 64 * 4
        assert got["all-to-all"] == 2 * 64 * 4
        assert got["collective-permute"] == 32

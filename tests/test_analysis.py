"""The compilation-contract analyzer and the repro lint.

Three layers:

* contract fields — a known-good and a known-bad fixture per
  :class:`~repro.analysis.contracts.CompilationContract` field;
* lint rules — a firing and a non-firing snippet per REPRO-00x rule, plus
  noqa/scoping/baseline mechanics;
* integration — every registered backend exposes a contract and passes it,
  and ``scripts/check_contracts.py --seed-violation`` turns the exit code
  red.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import (CALLBACK_PRIMITIVES,
                                      COLLECTIVE_HLO_OPS,
                                      CompilationContract, ContractProbe,
                                      check_contract, count_traces,
                                      host_probe, jaxpr_summary, run_probe)
from repro.analysis.lint import (RULES, LintFinding, diff_against_baseline,
                                 lint_source)
from repro.core.registry import Registry

REPO = Path(__file__).resolve().parent.parent


def _violating_fields(report):
    return {v.field for v in report.violations}


# ---------------------------------------------------------------------------
# contract fields: one good + one bad fixture each
# ---------------------------------------------------------------------------

class TestContractFields:
    def test_empty_contract_passes_trivially(self):
        rep = check_contract(lambda x: x + 1.0,
                             (jnp.ones(4),), CompilationContract())
        assert rep.ok and rep.n_primitives >= 1

    def test_forbidden_hlo(self):
        fn = lambda a: a @ a                              # noqa: E731
        args = (jnp.ones((8, 8)),)
        bad = check_contract(fn, args,
                             CompilationContract(forbidden_hlo=("dot",)))
        good = check_contract(fn, args,
                              CompilationContract(forbidden_hlo=("while",)))
        assert not bad.ok and _violating_fields(bad) == {"forbidden_hlo"}
        assert good.ok

    def test_required_hlo(self):
        def loop(x):
            return jax.lax.while_loop(lambda c: c[0] < 5,
                                      lambda c: (c[0] + 1, c[1] * 2.0),
                                      (0, x))[1]
        args = (jnp.ones(4),)
        good = check_contract(loop, args,
                              CompilationContract(required_hlo=("while",)))
        bad = check_contract(lambda x: x + 1.0, args,
                             CompilationContract(required_hlo=("while",)))
        assert good.ok
        assert not bad.ok and _violating_fields(bad) == {"required_hlo"}

    def test_donation(self):
        def step(state, delta):
            return state + delta
        args = (jnp.ones(16), jnp.ones(16))
        donated = jax.jit(step, donate_argnums=(0,))
        good = check_contract(donated, args,
                              CompilationContract(donation=True))
        bad = check_contract(jax.jit(step), args,
                             CompilationContract(donation=True))
        assert good.ok
        assert not bad.ok and _violating_fields(bad) == {"donation"}

    def test_max_primitives(self):
        fn = lambda x: x * 2 + 1 - x / 3                  # noqa: E731
        args = (jnp.ones(4),)
        good = check_contract(fn, args,
                              CompilationContract(max_primitives=32))
        bad = check_contract(fn, args,
                             CompilationContract(max_primitives=1))
        assert good.ok
        assert not bad.ok and _violating_fields(bad) == {"max_primitives"}
        # The breakdown names the offending primitives.
        assert "primitives > budget" in str(bad.violations[0])

    def test_dtype_ceiling(self):
        fn = lambda x: x.astype(jnp.float64) * 2.0        # noqa: E731
        args = (jnp.ones(4, jnp.float32),)
        bad = check_contract(fn, args,
                             CompilationContract(dtype_ceiling="float32"),
                             x64=True)
        good = check_contract(fn, args,
                              CompilationContract(dtype_ceiling="float64"),
                              x64=True)
        assert not bad.ok and _violating_fields(bad) == {"dtype_ceiling"}
        assert good.ok and "float64" in good.dtypes

    def test_forbid_callbacks_in_scan_body(self):
        def noisy(x):
            def body(c, _):
                jax.debug.print("c={c}", c=c)
                return c + jnp.sum(x), None
            return jax.lax.scan(body, 0.0, None, length=3)[0]
        bad = check_contract(noisy, (jnp.ones(4),),
                             CompilationContract(forbid_callbacks=True))
        assert not bad.ok and _violating_fields(bad) == {"forbid_callbacks"}
        assert "scan/while body" in str(bad.violations[0])
        ok = check_contract(noisy, (jnp.ones(4),),
                            CompilationContract(forbid_callbacks=False))
        assert ok.ok

    def test_forbid_callbacks_outside_loop(self):
        def noisy(x):
            jax.debug.print("x={x}", x=x)
            return x + 1.0
        bad = check_contract(noisy, (jnp.ones(4),),
                             CompilationContract(forbid_callbacks=True))
        assert not bad.ok
        assert "in the traced body" in str(bad.violations[0])

    def test_max_traces(self):
        fn = lambda x: x * 2.0                            # noqa: E731
        # Three shapes -> three traces on a fresh jit.
        workload = [((jnp.ones(n),), {}) for n in (2, 3, 3, 4)]
        n = count_traces(fn, workload)
        assert n == 3
        bad = check_contract(fn, (jnp.ones(2),),
                             CompilationContract(max_traces=2), n_traces=n)
        good = check_contract(fn, (jnp.ones(2),),
                              CompilationContract(max_traces=3), n_traces=n)
        assert not bad.ok and _violating_fields(bad) == {"max_traces"}
        assert good.ok

    def test_static_argnums_skip_nonarray_operands(self):
        def fn(tag, x, scale):
            assert isinstance(tag, str)
            return x * scale
        jitted = jax.jit(fn, static_argnums=(0, 2))
        rep = check_contract(jitted, ("hot", jnp.ones(4), 2.0),
                             CompilationContract(), static_argnums=(0, 2))
        assert rep.ok

    def test_jaxpr_summary_descends_into_scan(self):
        def fn(x):
            return jax.lax.scan(lambda c, _: (c * 2.0, None), x, None,
                                length=3)[0]
        prims, _ = jaxpr_summary(jax.make_jaxpr(fn)(jnp.ones(2)))
        in_loop = [p for p, loop in prims if loop]
        assert "mul" in in_loop


# ---------------------------------------------------------------------------
# probes + registry attachment
# ---------------------------------------------------------------------------

class TestProbesAndRegistry:
    def test_host_probe_passes_with_note(self):
        rep = run_probe(host_probe("x:y", "numpy oracle"))
        assert rep.ok and "numpy oracle" in rep.note

    def test_run_probe_checks_contract(self):
        probe = ContractProbe(
            contract=CompilationContract(name="t", max_primitives=1),
            fn=lambda x: x * 2 + 1, args=(jnp.ones(2),))
        rep = run_probe(probe)
        assert not rep.ok and rep.name == "t"

    def test_attach_requires_registered_name(self):
        reg = Registry("widget")
        with pytest.raises(ValueError, match="unknown widget"):
            reg.attach_contract("nope", lambda: None)

    def test_contract_for_missing_raises(self):
        reg = Registry("widget")
        reg.register("a", object())
        with pytest.raises(ValueError, match="no attached compilation"):
            reg.contract_for("a")
        assert not reg.has_contract("a")

    def test_unregister_and_override_pop_contract(self):
        reg = Registry("widget")
        reg.register("a", object())
        reg.attach_contract("a", lambda: host_probe("a", ""))
        assert reg.has_contract("a")
        reg.register("a", object(), override=True)
        assert not reg.has_contract("a")      # stale contract dropped
        reg.attach_contract("a", lambda: host_probe("a", ""))
        reg.unregister("a")
        reg.register("a", object())
        assert not reg.has_contract("a")

    def test_every_registered_backend_has_a_passing_contract(self):
        import repro.core.anomaly          # noqa: F401
        import repro.core.demeter          # noqa: F401
        import repro.core.forecast_bank    # noqa: F401
        import repro.dsp.executor          # noqa: F401
        from repro.core.registry import (DETECTOR_BACKENDS, FIT_BACKENDS,
                                         FORECAST_BACKENDS, SIM_ENGINES)
        for reg in (SIM_ENGINES, FIT_BACKENDS, FORECAST_BACKENDS,
                    DETECTOR_BACKENDS):
            for name in reg:
                assert reg.has_contract(name), \
                    f"{reg.kind}:{name} registered without a contract"
                probes = reg.contract_for(name)()
                for p in (probes if isinstance(probes, list) else [probes]):
                    rep = run_probe(p)
                    assert rep.ok, rep.summary()

    def test_sharded_contract_forbids_collectives_and_pins_donation(self):
        from repro.dsp.executor import SHARDED_STEP_CONTRACT
        assert set(COLLECTIVE_HLO_OPS) <= set(
            SHARDED_STEP_CONTRACT.forbidden_hlo)
        assert SHARDED_STEP_CONTRACT.donation is True


# ---------------------------------------------------------------------------
# lint rules: firing + non-firing snippet per rule
# ---------------------------------------------------------------------------

def _codes(findings):
    return [f.rule for f in findings]


class TestLintRules:
    def test_rule_001_np_call_in_jit_body(self):
        bad = ("import jax, numpy as np\n"
               "@jax.jit\n"
               "def f(x):\n"
               "    return np.sum(x)\n")
        good = bad.replace("np.sum", "jnp.sum")
        assert _codes(lint_source(bad, "src/repro/core/m.py")) == ["REPRO-001"]
        assert lint_source(good, "src/repro/core/m.py") == []

    def test_rule_001_matches_partial_jit(self):
        bad = ("from functools import partial\n"
               "import jax, numpy as np\n"
               "@partial(jax.jit, static_argnames=('n',))\n"
               "def f(x, n):\n"
               "    return np.zeros(n) + x\n")
        assert "REPRO-001" in _codes(lint_source(bad, "src/repro/core/m.py"))

    def test_rule_002_key_reuse(self):
        bad = ("import jax\n"
               "def f(key):\n"
               "    a = jax.random.normal(key, (3,))\n"
               "    b = jax.random.uniform(key, (3,))\n"
               "    return a + b\n")
        good = ("import jax\n"
                "def f(key):\n"
                "    k1, key = jax.random.split(key)\n"
                "    a = jax.random.normal(k1, (3,))\n"
                "    key = jax.random.fold_in(key, 1)\n"
                "    b = jax.random.uniform(key, (3,))\n"
                "    return a + b\n")
        assert _codes(lint_source(bad, "src/repro/core/m.py")) == ["REPRO-002"]
        assert lint_source(good, "src/repro/core/m.py") == []

    def test_rule_002_reassignment_resets_ledger(self):
        ok = ("import jax\n"
              "def f(key):\n"
              "    a = jax.random.normal(key, (3,))\n"
              "    key = jax.random.split(key)[0]\n"
              "    b = jax.random.normal(key, (3,))\n"
              "    return a + b\n")
        assert lint_source(ok, "src/repro/core/m.py") == []

    def test_rule_003_scenario_loop_in_bank_code(self):
        bad = ("def step(self, rates):\n"
               "    for i in range(self.n_scenarios):\n"
               "        self.one(i)\n")
        # Same code outside dsp/ or core/*bank* files: out of scope.
        assert _codes(lint_source(bad, "src/repro/dsp/engine.py")) \
            == ["REPRO-003"]
        assert lint_source(bad, "src/repro/core/demeter.py") == []
        good = ("def step(self, rates):\n"
                "    for i in range(self.n_retries):\n"
                "        self.one(i)\n")
        assert lint_source(good, "src/repro/dsp/engine.py") == []

    def test_rule_003_zip_over_jobs(self):
        bad = ("def step(self, rates):\n"
               "    for job, r in zip(self.jobs, rates):\n"
               "        job.step(r)\n")
        assert _codes(lint_source(bad, "src/repro/dsp/engine.py")) \
            == ["REPRO-003"]

    def test_rule_004_registry_poke(self):
        bad = "CONTROLLERS._entries['mine'] = Thing()\n"
        good = "CONTROLLERS.register('mine', Thing())\n"
        assert _codes(lint_source(bad, "src/repro/dsp/plugin.py")) \
            == ["REPRO-004"]
        assert lint_source(good, "src/repro/dsp/plugin.py") == []
        # Registry's own implementation is exempt.
        assert lint_source(bad, "src/repro/core/registry.py") == []

    def test_rule_005_f64_outside_oracles(self):
        bad = ("import jax.numpy as jnp\n"
               "def f(x):\n"
               "    return x.astype(jnp.float64)\n")
        assert _codes(lint_source(bad, "src/repro/core/gp_bank.py")) \
            == ["REPRO-005"]
        # Allow-listed oracle module: deliberate f64 is the point.
        assert lint_source(bad, "src/repro/core/gp.py") == []
        bad_str = ("def f(x):\n"
                   "    return x.astype('float64')\n")
        assert _codes(lint_source(bad_str, "src/repro/core/gp_bank.py")) \
            == ["REPRO-005"]

    def test_noqa_with_code_suppresses(self):
        src = ("import jax.numpy as jnp\n"
               "def f(x):\n"
               "    return x.astype(jnp.float64)  # noqa: REPRO-005\n")
        assert lint_source(src, "src/repro/core/gp_bank.py") == []

    def test_bare_noqa_does_not_suppress(self):
        src = ("import jax.numpy as jnp\n"
               "def f(x):\n"
               "    return x.astype(jnp.float64)  # noqa\n")
        assert _codes(lint_source(src, "src/repro/core/gp_bank.py")) \
            == ["REPRO-005"]

    def test_syntax_error_reports_repro_000(self):
        assert _codes(lint_source("def f(:\n", "src/x.py")) == ["REPRO-000"]

    def test_rules_table_is_complete(self):
        assert [r.code for r in RULES] == [
            "REPRO-001", "REPRO-002", "REPRO-003", "REPRO-004", "REPRO-005"]
        assert all(r.title and r.rationale for r in RULES)


class TestBaseline:
    def _finding(self, rule="REPRO-005", path="a.py", line=3,
                 snippet="x.astype(jnp.float64)"):
        return LintFinding(rule, path, line, 0, "msg", snippet)

    def test_baselined_finding_is_not_new(self):
        f = self._finding()
        new, fixed = diff_against_baseline([f], [f.to_dict()])
        assert new == [] and fixed == []

    def test_line_drift_does_not_churn(self):
        f = self._finding(line=3)
        base = self._finding(line=99).to_dict()
        new, fixed = diff_against_baseline([f], [base])
        assert new == [] and fixed == []

    def test_new_and_fixed(self):
        cur = self._finding(snippet="b")
        base = self._finding(snippet="a").to_dict()
        new, fixed = diff_against_baseline([cur], [base])
        assert [f.snippet for f in new] == ["b"]
        assert [e["snippet"] for e in fixed] == ["a"]

    def test_multiplicity(self):
        f = self._finding()
        new, _ = diff_against_baseline([f, f], [f.to_dict()])
        assert len(new) == 1       # second occurrence is genuinely new


# ---------------------------------------------------------------------------
# the scripts (subprocess: what CI actually runs)
# ---------------------------------------------------------------------------

def _run(script, *argv):
    # Inherit the full environment: a stripped env (no HOME etc.) sends
    # jax's backend discovery into multi-minute timeout sleeps.
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / script), *argv],
        capture_output=True, text=True, cwd=REPO, env=env)


class TestScripts:
    def test_seeded_violation_turns_red(self, tmp_path):
        out = tmp_path / "contracts.json"
        res = _run("check_contracts.py", "--seed-violation",
                   "--only", "seeded-violation", "--json", str(out))
        assert res.returncode == 1, res.stdout + res.stderr
        payload = json.loads(out.read_text())
        assert payload["ok"] is False
        fields = {v["field"] for r in payload["reports"]
                  for v in r["violations"]}
        assert fields == {"donation", "dtype_ceiling", "forbid_callbacks"}

    def test_host_only_entries_pass_quickly(self):
        res = _run("check_contracts.py", "--only", "engine:batched")
        assert res.returncode == 0, res.stdout + res.stderr
        assert "1/1 contracts hold" in res.stdout

    def test_lint_runner_is_clean_against_baseline(self, tmp_path):
        out = tmp_path / "lint.json"
        res = _run("lint_repro.py", "--json", str(out))
        assert res.returncode == 0, res.stdout + res.stderr
        payload = json.loads(out.read_text())
        assert payload["ok"] is True and payload["new"] == []

    def test_rules_listing(self):
        res = _run("lint_repro.py", "--rules")
        assert res.returncode == 0
        for code in ("REPRO-001", "REPRO-005"):
            assert code in res.stdout

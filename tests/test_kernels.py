"""Per-kernel allclose sweeps: interpret-mode Pallas vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_tick import fused_tick
from repro.kernels.grouped_matmul import (grouped_matmul,
                                          sort_tokens_for_experts)
from repro.kernels.rmsnorm import fused_rmsnorm
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,sq,hq,hkv,d", [
        (2, 256, 4, 2, 64),      # GQA
        (1, 128, 8, 8, 128),     # MHA
        (2, 256, 4, 1, 64),      # MQA
        (1, 384, 2, 2, 256),     # gemma head_dim
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, b, sq, hq, hkv, d, causal):
        q = jnp.asarray(RNG.normal(size=(b, sq, hq, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, sq, hkv, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, sq, hkv, d)), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        q = jnp.asarray(RNG.normal(size=(1, 128, 4, 64)), dtype)
        k = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), dtype)
        v = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.float32(out), np.float32(want),
                                   **_tol(dtype))
        assert out.dtype == dtype

    def test_block_shape_independent(self):
        q = jnp.asarray(RNG.normal(size=(1, 512, 2, 64)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 512, 2, 64)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 512, 2, 64)), jnp.float32)
        a = flash_attention(q, k, v, blk_q=128, blk_kv=128, interpret=True)
        b = flash_attention(q, k, v, blk_q=256, blk_kv=64, interpret=True)
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,smax,hq,hkv,d", [
        (2, 512, 8, 2, 64), (4, 256, 4, 4, 128), (1, 1024, 16, 1, 128),
    ])
    def test_ragged_lengths(self, b, smax, hq, hkv, d):
        q = jnp.asarray(RNG.normal(size=(b, 1, hq, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, smax, hkv, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, smax, hkv, d)), jnp.float32)
        lengths = jnp.asarray(RNG.integers(1, smax, b), jnp.int32)
        out = decode_attention(q, k, v, lengths, interpret=True)
        want = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_length_one_attends_first_position_only(self):
        b, smax, h, d = 1, 256, 2, 64
        q = jnp.asarray(RNG.normal(size=(b, 1, h, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, smax, h, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, smax, h, d)), jnp.float32)
        out = decode_attention(q, k, v, jnp.asarray([1]), interpret=True)
        np.testing.assert_allclose(out[0, 0], v[0, 0], atol=1e-5)


class TestSSDScan:
    @pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
        (2, 512, 4, 64, 1, 128, 128),
        (1, 256, 8, 64, 2, 128, 256),
        (2, 256, 4, 64, 4, 128, 128),
    ])
    def test_matches_reference(self, b, s, h, p, g, n, chunk):
        x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
        a_log = jnp.asarray(RNG.uniform(0, 1.5, (h,)), jnp.float32)
        bm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
        cm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
        y, st = ssd_scan(x, dt, a_log, bm, cm, chunk=chunk, interpret=True)
        yr, sr = ref.ssd_scan_ref(x, dt, a_log, bm, cm, chunk=chunk)
        np.testing.assert_allclose(y, yr, atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(st, sr, atol=5e-5, rtol=5e-5)

    def test_state_continuity_chunks(self):
        """Final state equals the sequential recurrence's final state."""
        from repro.models.mamba2 import ssd_decode_step
        b, s, h, p, n = 1, 128, 2, 64, 128
        x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
        a_log = jnp.asarray(RNG.uniform(0, 1.0, (h,)), jnp.float32)
        bm = jnp.asarray(RNG.normal(size=(b, s, 1, n)), jnp.float32)
        cm = jnp.asarray(RNG.normal(size=(b, s, 1, n)), jnp.float32)
        _, st = ssd_scan(x, dt, a_log, bm, cm, chunk=64, interpret=True)
        state = jnp.zeros((b, h, p, n))
        for t in range(s):
            _, state = ssd_decode_step(state, x[:, t], dt[:, t], a_log,
                                       bm[:, t], cm[:, t])
        np.testing.assert_allclose(st, state, atol=1e-4, rtol=1e-4)


class TestGroupedMatmul:
    @pytest.mark.parametrize("n_tok,e,k,n", [
        (300, 4, 128, 256), (1000, 8, 256, 128), (64, 2, 128, 128),
    ])
    def test_matches_reference(self, n_tok, e, k, n):
        x = RNG.normal(size=(n_tok, k)).astype(np.float32)
        eids = RNG.integers(0, e, n_tok)
        lhs, tiles, inv, mask = sort_tokens_for_experts(x, eids, e, 128)
        rhs = jnp.asarray(RNG.normal(size=(e, k, n)), jnp.float32)
        out = grouped_matmul(jnp.asarray(lhs), rhs, jnp.asarray(tiles),
                             interpret=True)
        want = ref.grouped_matmul_ref(lhs, rhs, tiles, 128)
        np.testing.assert_allclose(out, want, atol=1e-3, rtol=1e-3)

    def test_per_token_expert_routing(self):
        """Gather-back equals per-token x @ W[expert]."""
        x = RNG.normal(size=(100, 128)).astype(np.float32)
        eids = RNG.integers(0, 4, 100)
        lhs, tiles, inv, mask = sort_tokens_for_experts(x, eids, 4, 128)
        rhs = RNG.normal(size=(4, 128, 64)).astype(np.float32)
        out = np.asarray(grouped_matmul(jnp.asarray(lhs), jnp.asarray(rhs),
                                        jnp.asarray(tiles), interpret=True))
        for row, src in zip(out[mask], inv[mask]):
            want = x[src] @ rhs[eids[src]]
            np.testing.assert_allclose(row, want, atol=1e-3, rtol=1e-3)


class TestFusedTick:
    """Fused sweep tick (lag update + detector observe + rank-1 RLS) vs the
    pure-jnp oracle the CPU path of the fused sweep engine runs. float64:
    the DSP engines execute under enable_x64 to mirror the NumPy oracles."""

    def _operands(self, n, seed=0):
        rng = np.random.default_rng(seed)
        return dict(
            lag=jnp.asarray(rng.uniform(0.0, 1e5, n)),
            lag_add=jnp.asarray(rng.uniform(0.0, 1e4, n)),
            rates=jnp.asarray(rng.uniform(1e4, 9e4, n)),
            cap=jnp.asarray(rng.uniform(1e4, 8e4, n)),
            down_pre=jnp.asarray(rng.random(n) < 0.3),
            w=jnp.asarray(rng.normal(size=(n, 2)) * 0.1),
            P=jnp.asarray(np.broadcast_to(10.0 * np.eye(2),
                                          (n, 2, 2)).copy()),
            y_prev=jnp.asarray(rng.uniform(0.0, 12.0, n)),
        )

    @pytest.mark.parametrize("n", [3, 8, 37])   # sub-block, exact, ragged
    def test_matches_reference(self, n):
        from jax.experimental import enable_x64
        with enable_x64():
            ops = self._operands(n, seed=n)
            got = fused_tick(**ops, lam=0.995, thresh=3.0, dt=5.0,
                             interpret=True)
            want = ref.fused_tick_ref(
                ops["lag"], ops["lag_add"], ops["rates"], ops["cap"],
                ops["down_pre"], ops["w"], ops["P"], ops["y_prev"],
                0.995, 3.0, 5.0)
        names = ("new_lag", "w'", "P'", "err", "flag")
        for g, r, name in zip(got[:4], want[:4], names):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       rtol=1e-12, atol=1e-12,
                                       err_msg=name)
        np.testing.assert_array_equal(np.asarray(got[4]),
                                      np.asarray(want[4]), err_msg="flag")

    def test_lag_update_matches_step_batch_arrays(self):
        # The kernel's lag arithmetic must be the simulator's, op for op —
        # the fused engine takes its carry from the tick while the metrics
        # come from step_batch_arrays, so any drift would desync them.
        # 1e-12, not bit-for-bit: these are two separately compiled
        # dispatches, and XLA contracts multiply-adds into FMAs
        # differently per module (inside the engine's single compiled scan
        # the two expressions do agree exactly).
        from jax.experimental import enable_x64

        from repro.dsp import ClusterModel
        from repro.dsp.simulator import step_batch_arrays
        n = 16
        with enable_x64():
            ops = self._operands(n, seed=1)
            rows = jnp.ones(n)
            new_lag, _ = step_batch_arrays(
                ClusterModel(), ops["lag"], ops["lag_add"], ops["rates"],
                rows * 4.0, rows, rows * 4096.0, rows, ops["cap"],
                ops["down_pre"], ops["down_pre"],
                jnp.zeros(n), jnp.zeros(n), 5.0)
            tick_lag = fused_tick(**ops, lam=0.995, thresh=3.0, dt=5.0,
                                  interpret=True)[0]
        np.testing.assert_allclose(np.asarray(tick_lag),
                                   np.asarray(new_lag),
                                   rtol=1e-12, atol=1e-12)


class TestFusedRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 37, 512), (2, 256, 128), (7, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, shape, dtype):
        x = jnp.asarray(RNG.normal(size=shape), dtype)
        res = jnp.asarray(RNG.normal(size=shape), dtype)
        sc = jnp.asarray(RNG.normal(size=shape[-1:]) * 0.1, dtype)
        y, s = fused_rmsnorm(x, res, sc, interpret=True)
        yr, sr = ref.fused_rmsnorm_ref(x, res, sc)
        np.testing.assert_allclose(np.float32(y), np.float32(yr),
                                   **_tol(dtype))
        np.testing.assert_allclose(np.float32(s), np.float32(sr),
                                   **_tol(dtype))

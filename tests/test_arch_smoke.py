"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + no NaNs; decode continuity for causal archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import (decode_step, encode, forward, init_cache,
                          init_params, logits_from_hidden, param_count,
                          prefill, train_loss)

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, rng):
    if cfg.frontend and cfg.frontend.kind == "audio":
        return {"frames": jnp.asarray(
                    rng.standard_normal((B, S, cfg.frontend.d_in)),
                    jnp.float32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                   (B, S)), jnp.int32),
                "loss_mask": jnp.ones((B, S), jnp.float32)}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.frontend and cfg.frontend.kind == "vision":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend.prefix_len,
                                 cfg.frontend.d_in)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = smoke_config(arch)
    params = init_params(KEY, cfg, dtype=jnp.float32)
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: train_loss(p, cfg, b))(params,
                                                                batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    h, _, aux = forward(params, cfg, batch)
    assert h.shape == (B, S, cfg.d_model)
    logits = logits_from_hidden(params, cfg, h)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if smoke_config(a).supports_decode])
def test_decode_continuity(arch):
    """prefill(16) + decode(1) == full forward(17) — exact cache semantics."""
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 17), 0,
                              cfg.vocab_size)
    batch17 = {"tokens": toks}
    if cfg.frontend and cfg.frontend.kind == "vision":
        patches = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.frontend.prefix_len,
                                    cfg.frontend.d_in))
        batch17["patches"] = patches
    h, _, _ = forward(params, cfg, batch17)
    want = logits_from_hidden(params, cfg, h)[:, -1]

    cache = init_cache(cfg, B, 64, dtype=jnp.float32)
    batch16 = dict(batch17)
    batch16["tokens"] = toks[:, :16]
    _, cache = prefill(params, cfg, batch16, cache)
    got, cache = decode_step(params, cfg, toks[:, 16:17].astype(jnp.int32),
                             cache)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=5e-4)
    assert int(cache["index"]) == 17


def test_encoder_head_shape(rng):
    cfg = smoke_config("hubert_xlarge")
    params = init_params(KEY, cfg, dtype=jnp.float32)
    logits = encode(params, cfg, make_batch(cfg, rng))
    assert logits.shape == (B, S, cfg.vocab_size)


@pytest.mark.parametrize("arch,expected_b", [
    ("deepseek_7b", 6.9), ("mistral_nemo_12b", 12.2), ("qwen2_7b", 7.6),
    ("gemma_7b", 8.5), ("pixtral_12b", 12.2), ("deepseek_moe_16b", 16.4),
    ("deepseek_v2_lite_16b", 15.7), ("mamba2_1p3b", 1.3),
    ("zamba2_2p7b", 2.5), ("hubert_xlarge", 0.95),
])
def test_full_config_param_counts(arch, expected_b):
    n = param_count(get_config(arch)) / 1e9
    assert n == pytest.approx(expected_b, rel=0.08), \
        f"{arch}: {n:.2f}B vs expected ~{expected_b}B"


@pytest.mark.parametrize("arch", ["mamba2_1p3b", "zamba2_2p7b"])
def test_ssm_state_is_constant_size(arch):
    """The long_500k eligibility: decode state does not grow with context."""
    cfg = smoke_config(arch)
    c64 = init_cache(cfg, 1, 64)
    c128 = init_cache(cfg, 1, 128)
    if cfg.family == "ssm":   # pure SSM: no per-position cache at all
        s64 = sum(np.prod(x.shape) for x in jax.tree.leaves(c64))
        s128 = sum(np.prod(x.shape) for x in jax.tree.leaves(c128))
        assert s64 == s128
    else:                     # hybrid: only the shared-attn KV grows
        assert c64["layers"]["mamba"]["ssd"].shape \
            == c128["layers"]["mamba"]["ssd"].shape

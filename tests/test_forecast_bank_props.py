"""Property tests pinning ForecastBank/DetectorBank to the scalar oracles.

Random AR orders, differencing orders, forgetting factors and NaN/constant
streams must produce the same updates, rollouts and anomaly flags on both
backends. Needs the optional ``hypothesis`` dependency (the ``test``
extra); deterministic agreement tests live in ``test_forecast_bank.py``.

Agreement tolerances are loose-ish (1e-5 relative) because the RLS
recursion is numerically chaotic over long horizons — see
``docs/FORECAST.md``; streams here stay well inside the regime where the
two float paths agree.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property-based tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import (DetectorBank, HoltWinters, MetricDetector,
                        OnlineARIMA, SeasonalNaive, binned_forecast,
                        make_forecaster)

finite_vals = st.floats(min_value=1.0, max_value=1e5, allow_nan=False)
stream = st.lists(st.one_of(finite_vals, st.just(float("nan"))),
                  min_size=30, max_size=120)


def feed(values, *models):
    for v in values:
        for m in models:
            m.update(v)


@given(p=st.integers(1, 10), d=st.integers(0, 2),
       lam=st.floats(0.9, 0.999), values=stream)
@settings(max_examples=15, deadline=None)
def test_arima_bank_matches_scalar(p, d, lam, values):
    s = OnlineARIMA(p=p, d=d, forgetting=lam)
    v = make_forecaster("arima", backend="bank", p=p, d=d, forgetting=lam)
    feed(values, s, v)
    a, b = s.forecast(7), v.forecast(7)
    scale = 1.0 + np.max(np.abs(a))
    np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-5 * scale)
    assert s.n_observed == v.n_observed
    assert binned_forecast(v, 7, 3) == pytest.approx(
        binned_forecast(s, 7, 3), rel=1e-4, abs=1e-5 * scale)


@given(const=finite_vals, n=st.integers(10, 60),
       p=st.integers(1, 8), d=st.integers(0, 2))
@settings(max_examples=15, deadline=None)
def test_constant_stream_agreement(const, n, p, d):
    s = OnlineARIMA(p=p, d=d)
    v = make_forecaster("arima", backend="bank", p=p, d=d)
    feed([const] * n, s, v)
    a, b = s.forecast(5), v.forecast(5)
    np.testing.assert_allclose(b, a, rtol=1e-7, atol=1e-7 * (1 + abs(const)))


@given(alpha=st.floats(0.05, 0.95), beta=st.floats(0.01, 0.9),
       gamma=st.floats(0.01, 0.9), season=st.integers(0, 8), values=stream)
@settings(max_examples=15, deadline=None)
def test_holt_bank_matches_scalar(alpha, beta, gamma, season, values):
    kw = dict(alpha=alpha, beta=beta, gamma=gamma, season=season)
    s = HoltWinters(**kw)
    v = make_forecaster("holt", backend="bank", **kw)
    feed(values, s, v)
    a, b = s.forecast(6), v.forecast(6)
    np.testing.assert_allclose(b, a, rtol=1e-9,
                               atol=1e-9 * (1.0 + np.max(np.abs(a))))
    assert s.n_observed == v.n_observed


@given(season=st.integers(1, 10), values=stream)
@settings(max_examples=15, deadline=None)
def test_seasonal_naive_bank_matches_scalar(season, values):
    s = SeasonalNaive(season=season)
    v = make_forecaster("seasonal", backend="bank", season=season)
    feed(values, s, v)
    np.testing.assert_allclose(v.forecast(2 * season + 1),
                               s.forecast(2 * season + 1))


@given(base=st.floats(100.0, 1e4), noise=st.floats(0.001, 0.05),
       outage_at=st.integers(25, 50), seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_detector_flags_match_scalar(base, noise, outage_at, seed):
    rng = np.random.default_rng(seed)
    healthy = base * (1.0 + rng.normal(0, noise, 70))
    values = np.concatenate([healthy[:outage_at], np.zeros(10),
                             healthy[outage_at:]])
    det_s = MetricDetector("m")
    det_b = DetectorBank(1)
    for t, v in enumerate(values):
        assert bool(det_b.observe(np.array([v]))[0]) == det_s.observe(v), \
            f"flag diverged at step {t}"

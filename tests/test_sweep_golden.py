"""Golden-file regression for the sweep protocol (Table-3 semantics).

``tests/golden/sweep_small.json`` is the scalar oracle's full JSON digest
of a small paper-style grid (trace x controller under periodic failures) —
latency percentiles, usage integrals, recovery bookkeeping, reconfiguration
counts. Engine refactors must reproduce it:

* ``scalar`` and ``batched`` **bit-for-bit** (float repr round-trips
  exactly through JSON);
* ``sharded`` and ``fused`` at 1e-12 relative (the XLA:CPU FMA-contraction
  caveat, see docs/SCALING.md — both engines run the float64 step through
  XLA, which contracts multiply-adds; observed agreement is ~1e-15),
  asserted in-process for ``fused`` below and for both engines by the
  ``golden`` case of ``tests/helpers/sharded_diff.py`` under 2 virtual
  devices.

Regenerate after an *intentional* semantics change::

    PYTHONPATH=src python tests/helpers/sharded_diff.py --case golden --regen
"""
import json
from pathlib import Path

from repro.core import EngineConfig
from repro.dsp import run_sweep

from helpers.sharded_diff import GOLDEN_PATH, VOLATILE, _approx, _specs

DIFF_SCRIPT = Path(__file__).parent / "helpers" / "sharded_diff.py"


def _digest(result) -> dict:
    return {k: v for k, v in result.to_json().items() if k not in VOLATILE}


class TestGoldenSweep:
    def test_golden_file_exists_and_parses(self):
        golden = json.loads(GOLDEN_PATH.read_text())
        assert len(golden["scenarios"]) == 4
        assert golden["n_steps"] == 180
        for sc in golden["scenarios"]:
            assert sc["n_failures_injected"] == 2

    def test_scalar_oracle_reproduces_golden_bit_for_bit(self):
        res = run_sweep(_specs("golden"),
                        config=EngineConfig(sim_backend="scalar"))
        assert _digest(res) == json.loads(GOLDEN_PATH.read_text())

    def test_batched_engine_reproduces_golden_bit_for_bit(self):
        res = run_sweep(_specs("golden"), config=EngineConfig())
        assert _digest(res) == json.loads(GOLDEN_PATH.read_text())

    def test_fused_engine_reproduces_golden(self):
        # In-process, on whatever mesh this process has (1 device is fine —
        # interval fusion needs no mesh). 1e-12 relative, not bit-for-bit:
        # XLA:CPU contracts the float64 multiply-adds into FMAs.
        res = run_sweep(_specs("golden"),
                        config=EngineConfig(sim_backend="fused"))
        _approx(_digest(res), json.loads(GOLDEN_PATH.read_text()), 1e-12)

    def test_sharded_and_fused_engines_reproduce_golden(
            self, run_under_devices):
        out = run_under_devices(2, DIFF_SCRIPT,
                                "--case", "golden", "--devices", 2)
        assert "DIFF-OK case=golden devices=2" in out

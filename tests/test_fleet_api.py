"""Fleet API surface tests: in-process dict ops and the JSON-lines wire.

The subprocess test drives ``python -m repro.fleet.api`` end to end — the
exact transport a non-Python peer would use — and asserts the one-request /
one-response framing, backend resolution through ``FLEET_BACKENDS``, and
the uniform error shape.
"""
import io
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.fleet.api import FleetAPI, serve_jsonl
from repro.fleet.service import FleetConfig

REPO_ROOT = Path(__file__).resolve().parent.parent


def _api(**fleet_kw) -> FleetAPI:
    fleet_kw.setdefault("capacity", 4)
    fleet_kw.setdefault("profiling", False)
    return FleetAPI(fleet=FleetConfig(**fleet_kw))


class TestInProcess:
    def test_register_report_epoch_recommend(self):
        api = _api()
        r = api.handle({"op": "register_job", "job_id": "a",
                        "backend": "sim"})
        assert r["ok"] and r["row"] == 0 and r["backend"] == "sim"
        r = api.handle({"op": "report_telemetry", "job_id": "a", "t": 30.0,
                        "metrics": {"rate": 500.0, "latency": 1.2,
                                    "usage": 0.5}})
        assert r["ok"] and r["accepted"]
        r = api.handle({"op": "run_epoch"})
        assert r["ok"] and r["epoch"] == 1 and r["observed"] == 1
        r = api.handle({"op": "recommend", "job_id": "a"})
        assert r["ok"] and r["policy"] == "cold"
        assert r["epochs_observed"] == 1
        r = api.handle({"op": "stats"})
        assert r["ok"] and r["jobs"] == 1
        r = api.handle({"op": "deregister_job", "job_id": "a"})
        assert r["ok"]
        assert api.handle({"op": "stats"})["jobs"] == 0

    def test_default_backend_comes_from_engine_config(self):
        api = _api()
        assert api.controller.config.fleet_backend == "sim"
        r = api.handle({"op": "register_job", "job_id": "a"})
        assert r["ok"] and r["backend"] == "sim"

    def test_serving_backend_registers(self):
        api = _api()
        r = api.handle({"op": "register_job", "job_id": "s",
                        "backend": "serving",
                        "params": {"decode_step_s": 0.01}})
        assert r["ok"] and r["backend"] == "serving"
        rec = api.handle({"op": "recommend", "job_id": "s"})
        assert rec["ok"] and "replicas" in rec["config"]

    def test_error_shapes_are_uniform(self):
        api = _api()
        for req in ({"op": "frobnicate"},
                    {"op": "recommend", "job_id": "ghost"},
                    {"op": "register_job"},                 # missing job_id
                    {"op": "register_job", "job_id": "x",
                     "backend": "not-a-backend"},
                    {"op": "report_telemetry", "job_id": "x", "t": 1.0,
                     "metrics": {}}):
            r = api.handle(req)
            assert r["ok"] is False and isinstance(r["error"], str), req

    def test_unknown_backend_error_names_available(self):
        api = _api()
        r = api.handle({"op": "register_job", "job_id": "x",
                        "backend": "bogus"})
        assert not r["ok"] and "sim" in r["error"]


class TestJsonLines:
    def test_serve_jsonl_in_memory(self):
        requests = [
            {"op": "register_job", "job_id": "a", "backend": "sim"},
            {"op": "report_telemetry", "job_id": "a", "t": 30.0,
             "metrics": {"rate": 100.0, "latency": 1.0, "usage": 0.4}},
            {"op": "run_epoch"},
            "this is not json",
            {"op": "shutdown"},
            {"op": "stats"},                       # never reached
        ]
        lines = [r if isinstance(r, str) else json.dumps(r)
                 for r in requests]
        out = io.StringIO()
        served = serve_jsonl(_api(), io.StringIO("\n".join(lines) + "\n"),
                             out)
        responses = [json.loads(line) for line in
                     out.getvalue().strip().splitlines()]
        assert served == 5                         # stopped at shutdown
        assert responses[0]["ok"] and responses[0]["row"] == 0
        assert responses[2]["ok"] and responses[2]["epoch"] == 1
        assert not responses[3]["ok"] and "bad json" in responses[3]["error"]
        assert responses[4] == {"ok": True, "shutdown": True}

    @pytest.mark.slow
    def test_subprocess_round_trip(self):
        """The real wire: a child ``python -m repro.fleet`` on stdio."""
        env = os.environ.copy()
        env["PYTHONPATH"] = os.pathsep.join(
            [str(REPO_ROOT / "src")]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        requests = [
            {"op": "register_job", "job_id": "a", "backend": "sim"},
            {"op": "register_job", "job_id": "b", "backend": "sim",
             "params": {"seed": 3}},
            {"op": "report_telemetry", "job_id": "a", "t": 30.0,
             "metrics": {"rate": 500.0, "latency": 1.5, "usage": 0.5}},
            {"op": "run_epoch"},
            {"op": "recommend", "job_id": "a"},
            {"op": "nope"},
            {"op": "stats"},
            {"op": "shutdown"},
        ]
        proc = subprocess.run(
            [sys.executable, "-m", "repro.fleet", "--capacity", "4",
             "--no-profiling"],
            input="\n".join(json.dumps(r) for r in requests) + "\n",
            env=env, cwd=str(REPO_ROOT), capture_output=True, text=True,
            timeout=600.0)
        assert proc.returncode == 0, proc.stderr
        responses = [json.loads(line)
                     for line in proc.stdout.strip().splitlines()]
        assert len(responses) == len(requests)
        reg_a, reg_b, tel, epoch, rec, bad, stats, bye = responses
        assert reg_a["ok"] and reg_a["row"] == 0
        assert reg_b["ok"] and reg_b["row"] == 1
        assert tel["ok"] and tel["accepted"] is True
        assert epoch["ok"] and epoch["epoch"] == 1 and epoch["jobs"] == 2
        assert rec["ok"] and rec["policy"] == "cold"
        assert not bad["ok"] and "unknown op" in bad["error"]
        assert stats["ok"] and stats["jobs"] == 2 \
            and len(stats["decision_digest"]) == 64
        assert bye == {"ok": True, "shutdown": True}
